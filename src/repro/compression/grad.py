"""Error-feedback PLA-compressed cross-pod gradient reduction.

This is the paper's scenario (1) — "reduce transmissions between sensors
and the datacenter" — mapped onto the multi-pod mesh: each pod produces a
full (data+model reduced) gradient; instead of an fp32/bf16 all-reduce over
the slow cross-pod links, each pod PLA-compresses its gradient rows
(SingleStream-style ``(n, a, v)`` records with the paper's 256-point cap),
all-gathers the *records* over the ``pod`` axis, reconstructs and averages
locally.  The compression residual is carried in an error-feedback buffer
so training stays unbiased in expectation (Karimireddy et al. style EF).

Wire format per row (fixed budget K slots, shape-static for collectives):
``seg_end: uint8`` + ``(a, v): bfloat16`` = 5 bytes/slot, versus
``chunk * 4`` bytes raw — a fixed ≥ (chunk / (5K/4)) reduction, plus the
protocol-level accounting via :func:`repro.core.jax_pla.singlestream_nbytes`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.compat import sharding as compat_sharding
from repro.core.jax_pla import (PLARecords, angle_segment, decode_records,
                                linear_segment, propagate_lines, to_records,
                                singlestream_nbytes)

_SEGMENTERS = {"angle": angle_segment, "linear": linear_segment}


@dataclasses.dataclass(frozen=True)
class GradCompressionConfig:
    enabled: bool = True
    method: str = "angle"        # angle (O(1) state) | linear (best error)
    chunk: int = 256             # stream length (the paper's 1-byte cap)
    k_max: int = 32              # record slots per row (wire budget)
    eps_rel: float = 0.05        # eps = eps_rel * RMS(leaf)
    eps_ladder: int = 4          # per-row escalation: eps * 4^r, r < ladder
    min_leaf_size: int = 4096    # smaller leaves go uncompressed


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _rows(flat: jax.Array, chunk: int) -> jax.Array:
    n = flat.shape[0]
    rows = -(-n // chunk)
    pad = rows * chunk - n
    return jnp.pad(flat, (0, pad)).reshape(rows, chunk)


def pla_compress_leaf(g: jax.Array, cfg: GradCompressionConfig,
                      eps_rows: jax.Array | None = None
                      ) -> Tuple[PLARecords, jax.Array]:
    """Compress one gradient leaf; returns (records, per-row eps used).

    Rows whose segmentation overflows the K-slot budget escalate eps by 4x
    (up to ``eps_ladder`` rungs) — the adaptive-threshold extension the
    paper's §8 singles out as the natural next step; leftover overflow is
    absorbed by error feedback.

    ``eps_rows``: per-row base eps.  Error-feedback callers MUST pass eps
    derived from the *raw* gradient (not grad+residual): residual-scaled
    eps inflates itself and the EF loop diverges linearly (measured —
    tests/test_compression.py::test_error_feedback_converges_unbiased).
    """
    flat = g.reshape(-1).astype(jnp.float32)
    y = _rows(flat, cfg.chunk)
    if eps_rows is not None:
        base_eps = eps_rows
    else:
        # Per-row eps: rows of very different magnitude (e.g. embedding
        # rows) each get eps_rel of their own RMS.
        base_eps = cfg.eps_rel * jnp.sqrt(jnp.mean(y * y, axis=1) + 1e-20)

    cands = []
    for r in range(cfg.eps_ladder):
        eps_r = base_eps * (4.0 ** r)
        seg = _SEGMENTERS[cfg.method](y, eps_r, max_run=cfg.chunk)
        cands.append((to_records(seg, cfg.k_max), eps_r))
    # Per-row: first rung that fits the budget (else last rung).
    rec, eps_row = cands[-1][0], jnp.full((y.shape[0],), cands[-1][1])
    for cand, eps_r in reversed(cands[:-1]):
        fit = ~cand.overflow
        take = lambda a, b: jnp.where(fit.reshape((-1,) + (1,) * (a.ndim - 1)),
                                      a, b)
        rec = PLARecords(take(cand.seg_end, rec.seg_end),
                         take(cand.a, rec.a), take(cand.v, rec.v),
                         jnp.where(fit, cand.count, rec.count),
                         jnp.where(fit, cand.overflow, rec.overflow))
        eps_row = jnp.where(fit, eps_r, eps_row)

    rec = PLARecords(
        seg_end=rec.seg_end.astype(jnp.uint8),
        a=rec.a.astype(jnp.float16),
        v=rec.v.astype(jnp.float16),
        count=rec.count.astype(jnp.uint8),
        overflow=rec.overflow,
    )
    return rec, eps_row


def overflow_escape_rows(g: jax.Array, rec: PLARecords,
                         cfg: GradCompressionConfig) -> jax.Array:
    """Raw copies of overflow rows (zeros elsewhere) — the escape hatch
    that keeps the eps guarantee *unconditional*.  Without it a single
    overflow row's garbage tail feeds the EF residual and the loop blows
    up exponentially (measured).  Wire accounting: chunk*4 bytes per
    overflow row (production uses ragged transfers; the dense zero-filled
    array here is a static-shape artifact of the collective)."""
    y = _rows(g.reshape(-1).astype(jnp.float32), cfg.chunk)
    return jnp.where(rec.overflow[:, None], y, 0.0)


def apply_escape(decoded_rows: jax.Array, rec: PLARecords,
                 raw_rows: jax.Array) -> jax.Array:
    return jnp.where(rec.overflow[:, None], raw_rows, decoded_rows)


def pla_decompress_leaf(rec: PLARecords, shape, cfg: GradCompressionConfig
                        ) -> jax.Array:
    rec32 = PLARecords(rec.seg_end.astype(jnp.int32),
                       rec.a.astype(jnp.float32),
                       rec.v.astype(jnp.float32),
                       rec.count.astype(jnp.int32), rec.overflow)
    y = decode_records(rec32, cfg.chunk)
    n = 1
    for s in shape:
        n *= s
    return y.reshape(-1)[:n].reshape(shape)


def _should_compress(path_leaf, cfg: GradCompressionConfig) -> bool:
    return path_leaf.size >= cfg.min_leaf_size


def pod_compressed_mean(grads, ef, cfg: GradCompressionConfig,
                        axis_name: str = "pod"):
    """Cross-pod mean of gradients with PLA compression + error feedback.

    Must run inside ``shard_map`` with ``axis_name`` manual.  ``grads`` and
    ``ef`` are this pod's local values; returns (mean_grads, new_ef,
    stats).  Leaves below ``min_leaf_size`` take a plain ``psum``.
    """
    n_pods = compat_sharding.axis_size(axis_name)

    def one(g, e):
        g_raw = g.astype(jnp.float32)
        if not cfg.enabled or g_raw.size < cfg.min_leaf_size:
            g = g_raw + e
            return jax.lax.pmean(g, axis_name), jnp.zeros_like(g), \
                jnp.zeros((), jnp.float32)
        # eps anchored to the *raw* gradient scale (EF stability).
        yr = _rows(g_raw.reshape(-1), cfg.chunk)
        eps_rows = cfg.eps_rel * jnp.sqrt(jnp.mean(yr * yr, axis=1) + 1e-20)
        g = g_raw + e
        rec, eps = pla_compress_leaf(g, cfg, eps_rows=eps_rows)
        raw_esc = overflow_escape_rows(g, rec, cfg)

        def dec_rows(r, esc):
            rec32 = PLARecords(r.seg_end.astype(jnp.int32),
                               r.a.astype(jnp.float32),
                               r.v.astype(jnp.float32),
                               r.count.astype(jnp.int32), r.overflow)
            from repro.core.jax_pla import decode_records
            return apply_escape(decode_records(rec32, cfg.chunk), r, esc)

        local_rows = dec_rows(rec, raw_esc)
        n = g.size
        local_dec = local_rows.reshape(-1)[:n].reshape(g.shape)
        new_ef = g - local_dec          # residual stays local (EF)
        # Exchange records (+ escape rows) over the pod axis.
        if compat_sharding.partial_auto_shard_map_supported():
            gathered = jax.lax.all_gather((rec, raw_esc), axis_name)
            decoded = jax.vmap(lambda re: dec_rows(*re))(gathered)
            mean = decoded.mean(axis=0).reshape(-1)[:n].reshape(g.shape)
        else:
            # Decode is deterministic per pod, so pmean of the locally
            # decoded rows equals the mean of all pods' decoded records;
            # only decoded values (not records) cross the boundary here,
            # which keeps the collective psum-shaped — the only kind the
            # 0.4.x partitioner accepts under partial-manual shard_map.
            mean = jax.lax.pmean(local_rows, axis_name) \
                .reshape(-1)[:n].reshape(g.shape)
        n_over = rec.overflow.sum()
        nbytes = jnp.float32(rec.seg_end.size + 2 * rec.a.size
                             + 2 * rec.v.size + rec.count.size) \
            + n_over * cfg.chunk * 4.0
        return mean, new_ef, nbytes

    flat, treedef = jax.tree.flatten(grads)
    ef_flat = jax.tree.flatten(ef)[0]
    outs = [one(g, e) for g, e in zip(flat, ef_flat)]
    mean = treedef.unflatten([o[0] for o in outs])
    new_ef = treedef.unflatten([o[1] for o in outs])
    wire_bytes = sum(o[2] for o in outs)
    raw_bytes = sum(jnp.full((), g.size * 4, jnp.float32) for g in flat)
    # wire_bytes always reports the record protocol's traffic.  In the
    # 0.4.x fallback the simulation collective actually moves decoded
    # rows, so there the figure is *modeled* rather than measured —
    # flagged so telemetry consumers can tell the two apart.
    stats = {"wire_bytes": wire_bytes, "raw_bytes": raw_bytes,
             "n_pods": n_pods,
             "wire_is_modeled": jnp.float32(
                 0.0 if compat_sharding.partial_auto_shard_map_supported()
                 else 1.0)}
    return mean, new_ef, stats


def compression_report(grads, cfg: GradCompressionConfig) -> Dict[str, Any]:
    """Offline report: fixed-budget wire bytes + paper-protocol bytes +
    reconstruction error for each leaf (used by benchmarks)."""
    report = {}
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        name = jax.tree_util.keystr(path)
        if g.size < cfg.min_leaf_size:
            report[name] = {"raw_bytes": g.size * 4, "skipped": True}
            continue
        rec, eps = pla_compress_leaf(g, cfg)
        dec = pla_decompress_leaf(rec, g.shape, cfg)
        err = jnp.abs(dec - g.astype(jnp.float32)).max()
        rec32 = PLARecords(rec.seg_end.astype(jnp.int32),
                           rec.a.astype(jnp.float32),
                           rec.v.astype(jnp.float32),
                           rec.count.astype(jnp.int32), rec.overflow)
        proto_bytes = singlestream_nbytes(rec32, cfg.chunk).sum()
        report[name] = {
            "raw_bytes": int(g.size * 4),
            "fixed_wire_bytes": int(rec.seg_end.size + 2 * rec.a.size
                                    + 2 * rec.v.size + rec.count.size),
            "protocol_bytes": int(proto_bytes),
            "eps_base": float(eps.min()),
            "eps_max_used": float(eps.max()),
            "max_err": float(err),
            "overflow_rows": int(rec.overflow.sum()),
        }
    return report
