"""Host-side training-telemetry compression (paper scenario 1, verbatim).

Every host streams per-step metrics (loss, grad norm, per-layer stats) to a
coordinator/dashboard.  Each metric channel is a timestamped stream —
exactly the paper's setting — compressed with the *Linear* method (lowest
average error) under the *SingleStreamV* protocol (lowest latency, the
paper's Table 3 recommendation for scenario (1)).

By default the segmentation is driven off the carry-state streaming engine
(:mod:`repro.core.jax_pla`): appended values are pushed through
``step_chunk`` in small batches, so the per-flush work is O(new points)
with bounded latency instead of re-running the whole window's method at
send time.  The window's fitted segments are translated to the paper's
protocol records at flush (steps must be uniformly spaced for the
index-grid translation; irregular channels transparently fall back to the
exact sequential methods, as does ``streaming=False``).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import METHODS, PROTOCOLS, PROTOCOL_CAPS
from repro.core.protocols import encode_singlestreamv
from repro.core.types import Line, MethodOutput, Segment


def _segments_from_events(brk: np.ndarray, a: np.ndarray, v: np.ndarray,
                          ts: np.ndarray) -> MethodOutput:
    """Translate anchored index-grid events to t-space MethodOutput.

    Event k ends a segment at index ``e`` with the anchored line
    ``y(i) = v + a * (i - e)``; on a uniform grid ``t = t0 + d*i`` that is
    the line ``A*t + B`` with ``A = a/d``, ``B = v - a*e - A*t0``.
    """
    n = len(ts)
    d = float(ts[1] - ts[0]) if n > 1 else 1.0
    t0 = float(ts[0])
    ends = np.flatnonzero(brk)
    segments: List[Segment] = []
    i0 = 0
    for e in ends:
        e = int(e)
        A = float(a[e]) / d
        B = float(v[e]) - float(a[e]) * e - A * t0
        segments.append(Segment(i0=i0, i1=e + 1, line=Line(A, B),
                                finalized_at=min(e + 1, n - 1)))
        i0 = e + 1
    return MethodOutput(segments=segments, knots=[])


class TelemetryCompressor:
    """Buffers per-channel metric streams; flushes compressed bytes.

    Flush semantics mirror a periodic sender: every ``flush_every`` appended
    steps the buffered window is compressed and (simulated) transmitted.
    With ``streaming=True`` (default) each channel owns a
    :class:`repro.core.jax_pla.SegmenterState` that is advanced every
    ``step_every`` appends, so the flush only closes the trailing run.
    """

    def __init__(self, eps: float = 1e-3, method: str = "linear",
                 flush_every: int = 256, streaming: bool = True,
                 step_every: int = 32):
        if method not in METHODS:
            raise ValueError(f"unknown method {method!r}; "
                             f"have {sorted(METHODS)}")
        self.eps = eps
        self.method = method
        self.flush_every = flush_every
        # Only the jnp carry-state engine's methods stream; the remaining
        # sequential methods (continuous/mixed) keep the batch flush path.
        from repro.core.jax_pla import STREAMING_METHODS
        self.streaming = streaming and method in STREAMING_METHODS
        self.step_every = max(1, step_every)
        self.buffers: Dict[str, List[float]] = {}
        self.steps: Dict[str, List[int]] = {}
        self._states: Dict[str, object] = {}
        self._stepped: Dict[str, int] = {}
        self._events: Dict[str, List[Tuple[np.ndarray, np.ndarray,
                                           np.ndarray]]] = {}
        self.sent_bytes = 0
        self.raw_bytes = 0
        self.max_err_seen = 0.0

    def append(self, step: int, metrics: Dict[str, float]) -> Optional[bytes]:
        out = []
        for name, val in metrics.items():
            self.buffers.setdefault(name, []).append(float(val))
            self.steps.setdefault(name, []).append(step)
            if self.streaming:
                pend = len(self.buffers[name]) - self._stepped.get(name, 0)
                if pend >= self.step_every:
                    self._advance(name)
            if len(self.buffers[name]) >= self.flush_every:
                out.append(self._flush_channel(name))
        return b"".join(out) if out else None

    # ---- streaming engine plumbing ---------------------------------------

    def _advance(self, name: str) -> None:
        """Push not-yet-segmented values through the channel's carry state."""
        from repro.core import jax_pla
        done = self._stepped.get(name, 0)
        vals = self.buffers[name][done:]
        if not vals:
            return
        st = self._states.get(name)
        if st is None:
            st = jax_pla.init_state(
                self.method, 1, self.eps,
                max_run=PROTOCOL_CAPS["singlestreamv"])
        st, out = jax_pla.step_chunk(st, np.asarray(vals, np.float32)[None])
        self._states[name] = st
        self._stepped[name] = len(self.buffers[name])
        if out.breaks.shape[1]:
            self._events.setdefault(name, []).append(
                (np.asarray(out.breaks[0]), np.asarray(out.a[0]),
                 np.asarray(out.v[0])))

    def _streaming_records(self, name: str, ts: np.ndarray, ys: np.ndarray):
        """Close the channel's run and emit protocol records, or None when
        the channel needs the irregular-timestamps fallback."""
        from repro.core import jax_pla
        if len(ts) > 1:
            dt = np.diff(ts)
            if not np.allclose(dt, dt[0], rtol=1e-9, atol=0.0) or dt[0] <= 0:
                # Index-grid translation needs a uniform grid; drop the
                # carry (the window restarts either way) and fall back.
                self._states.pop(name, None)
                self._events.pop(name, None)
                return None
        self._advance(name)
        st, out_f = jax_pla.flush(self._states.pop(name))
        ev = self._events.pop(name, [])
        ev.append((np.asarray(out_f.breaks[0]), np.asarray(out_f.a[0]),
                   np.asarray(out_f.v[0])))
        brk = np.concatenate([e[0] for e in ev])
        a = np.concatenate([e[1] for e in ev])
        v = np.concatenate([e[2] for e in ev])
        mo = _segments_from_events(brk, a, v, ts)
        return PROTOCOLS["singlestreamv"](mo, ts, ys)

    # ---- flush -----------------------------------------------------------

    def _flush_channel(self, name: str) -> bytes:
        ys = np.asarray(self.buffers[name])
        ts = np.asarray(self.steps[name], dtype=float)
        recs = self._streaming_records(name, ts, ys) if self.streaming \
            else None
        self.buffers[name] = []
        self.steps[name] = []
        self._stepped[name] = 0
        if recs is None:
            cap = PROTOCOL_CAPS["singlestreamv"]
            out = METHODS[self.method](ts, ys, self.eps, max_run=cap)
            recs = PROTOCOLS["singlestreamv"](out, ts, ys)
        blob = encode_singlestreamv(recs)
        self.sent_bytes += len(blob)
        self.raw_bytes += 8 * len(ys)
        # Track the worst reconstruction error actually incurred.
        recon = np.full(len(ys), np.nan)
        for r in recs:
            for kk, i in enumerate(r.covers):
                recon[i] = r.values[kk]
        self.max_err_seen = max(self.max_err_seen,
                                float(np.abs(recon - ys).max()))
        return blob

    def flush_all(self) -> bytes:
        names = [n for n, b in self.buffers.items() if b]
        return b"".join(self._flush_channel(n) for n in names)

    @property
    def ratio(self) -> float:
        return self.sent_bytes / self.raw_bytes if self.raw_bytes else 0.0
