"""Host-side training-telemetry compression (paper scenario 1, verbatim).

Every host streams per-step metrics (loss, grad norm, per-layer stats) to a
coordinator/dashboard.  Each metric channel is a timestamped stream —
exactly the paper's setting — compressed with the *Linear* method (lowest
average error) under the *SingleStreamV* protocol (lowest latency, the
paper's Table 3 recommendation for scenario (1)).

Pure-Python sequential implementation (host side, tiny rates), using the
exact reference methods from :mod:`repro.core`.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core import METHODS, PROTOCOLS, PROTOCOL_CAPS
from repro.core.protocols import encode_singlestreamv


class TelemetryCompressor:
    """Buffers per-channel metric streams; flushes compressed bytes.

    Flush semantics mirror a periodic sender: every ``flush_every`` appended
    steps the buffered window is compressed and (simulated) transmitted.
    """

    def __init__(self, eps: float = 1e-3, method: str = "linear",
                 flush_every: int = 256):
        self.eps = eps
        self.method = method
        self.flush_every = flush_every
        self.buffers: Dict[str, List[float]] = {}
        self.steps: Dict[str, List[int]] = {}
        self.sent_bytes = 0
        self.raw_bytes = 0
        self.max_err_seen = 0.0

    def append(self, step: int, metrics: Dict[str, float]) -> Optional[bytes]:
        out = []
        for name, val in metrics.items():
            self.buffers.setdefault(name, []).append(float(val))
            self.steps.setdefault(name, []).append(step)
            if len(self.buffers[name]) >= self.flush_every:
                out.append(self._flush_channel(name))
        return b"".join(out) if out else None

    def _flush_channel(self, name: str) -> bytes:
        ys = np.asarray(self.buffers[name])
        ts = np.asarray(self.steps[name], dtype=float)
        self.buffers[name] = []
        self.steps[name] = []
        cap = PROTOCOL_CAPS["singlestreamv"]
        out = METHODS[self.method](ts, ys, self.eps, max_run=cap)
        recs = PROTOCOLS["singlestreamv"](out, ts, ys)
        blob = encode_singlestreamv(recs)
        self.sent_bytes += len(blob)
        self.raw_bytes += 8 * len(ys)
        # Track the worst reconstruction error actually incurred.
        recon = np.full(len(ys), np.nan)
        for r in recs:
            for kk, i in enumerate(r.covers):
                recon[i] = r.values[kk]
        self.max_err_seen = max(self.max_err_seen,
                                float(np.abs(recon - ys).max()))
        return blob

    def flush_all(self) -> bytes:
        names = [n for n, b in self.buffers.items() if b]
        return b"".join(self._flush_channel(n) for n in names)

    @property
    def ratio(self) -> float:
        return self.sent_bytes / self.raw_bytes if self.raw_bytes else 0.0
