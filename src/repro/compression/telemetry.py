"""Host-side training-telemetry compression (paper scenario 1, verbatim).

Every host streams per-step metrics (loss, grad norm, per-layer stats) to a
coordinator/dashboard.  Each metric channel is a timestamped stream —
exactly the paper's setting — compressed with the *Linear* method (lowest
average error) under the *SingleStreamV* protocol (lowest latency, the
paper's Table 3 recommendation for scenario (1)).

By default the whole path is incremental: appended values are pushed
through the carry-state segmentation engine
(:func:`repro.core.jax_pla.step_chunk`) in small batches, and the
finalized events flow straight into a
:class:`repro.core.protocol_engine.ProtocolEmitter`, which packs
**wire-ready SingleStreamV bytes as segments close** — the flush only
closes the trailing run and ships what is already encoded, so per-flush
work is O(new points) and the blob is bit-identical to the offline
codec.  Channels need uniformly spaced steps for the index-grid engine;
irregular channels transparently fall back to the exact sequential
methods + record codec (as does ``streaming=False``).

The deferred methods (``continuous`` / ``mixed``) stream too: their
``step_chunk`` releases a *data-dependent* number of columns (a segment
resolves only at the next break — the paper's extra segment of latency),
so the sender is **lag-aware** — it tracks each channel's released-column
watermark (:meth:`TelemetryCompressor.lag` = appended minus wire-ready
points) and lets the emitter buffer values ahead of their events; the
periodic flush closes the run, which releases the lagging tail.  The
window blob stays bit-identical to the offline batched codec.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core import METHODS, PROTOCOLS, PROTOCOL_CAPS
from repro.core.protocol_engine import ProtocolEmitter
from repro.core.protocols import decode_singlestreamv, encode_singlestreamv


class TelemetryCompressor:
    """Buffers per-channel metric streams; flushes compressed bytes.

    Flush semantics mirror a periodic sender: every ``flush_every`` appended
    steps the buffered window is compressed and (simulated) transmitted.
    With ``streaming=True`` (default) each channel owns a
    :class:`repro.core.jax_pla.SegmenterState` plus a
    :class:`repro.core.protocol_engine.ProtocolEmitter`, both advanced
    every ``step_every`` appends, so wire bytes accumulate incrementally
    and the flush only closes the trailing run.
    """

    def __init__(self, eps: float = 1e-3, method: str = "linear",
                 flush_every: int = 256, streaming: bool = True,
                 step_every: int = 32):
        if method not in METHODS:
            raise ValueError(f"unknown method {method!r}; "
                             f"have {sorted(METHODS)}")
        self.eps = eps
        self.method = method
        self.flush_every = flush_every
        # Every streaming method feeds the per-flush wire path; the
        # deferred-output methods (continuous/mixed) release event columns
        # one segment late, which the lag-aware plumbing absorbs: the
        # emitter buffers values ahead of their events and the watermark
        # (self._released) tracks how much of each channel is wire-ready.
        from repro.core.jax_pla import STREAMING_METHODS
        self.streaming = streaming and method in STREAMING_METHODS
        self.step_every = max(1, step_every)
        self.buffers: Dict[str, List[float]] = {}
        self.steps: Dict[str, List[int]] = {}
        self._states: Dict[str, object] = {}
        self._emitters: Dict[str, ProtocolEmitter] = {}
        self._wire: Dict[str, bytearray] = {}
        self._stepped: Dict[str, int] = {}
        self._released: Dict[str, int] = {}   # wire-ready watermark
        self._irregular: Dict[str, bool] = {}
        self.sent_bytes = 0
        self.raw_bytes = 0
        self.max_err_seen = 0.0

    def append(self, step: int, metrics: Dict[str, float]) -> Optional[bytes]:
        out = []
        for name, val in metrics.items():
            self.buffers.setdefault(name, []).append(float(val))
            steps = self.steps.setdefault(name, [])
            steps.append(step)
            if self.streaming and not self._irregular.get(name):
                if len(steps) >= 3:
                    d = steps[1] - steps[0]
                    if d <= 0 or steps[-1] - steps[-2] != d:
                        self._drop_streaming(name)
                elif len(steps) == 2 and steps[1] - steps[0] <= 0:
                    self._drop_streaming(name)
            if self.streaming and not self._irregular.get(name):
                pend = len(self.buffers[name]) - self._stepped.get(name, 0)
                if pend >= self.step_every:
                    self._advance(name)
            if len(self.buffers[name]) >= self.flush_every:
                out.append(self._flush_channel(name))
        return b"".join(out) if out else None

    # ---- streaming engine plumbing ---------------------------------------

    def _drop_streaming(self, name: str) -> None:
        """Non-uniform grid: abandon the incremental state for this window
        (the exact sequential fallback recompresses it at flush)."""
        self._irregular[name] = True
        self._states.pop(name, None)
        self._emitters.pop(name, None)
        self._wire.pop(name, None)
        self._stepped[name] = 0
        self._released[name] = 0

    def _emitter(self, name: str) -> ProtocolEmitter:
        em = self._emitters.get(name)
        if em is None:
            steps = self.steps[name]
            d = float(steps[1] - steps[0]) if len(steps) > 1 else 1.0
            em = ProtocolEmitter("singlestreamv", 1, t0=float(steps[0]),
                                 dt=d)
            self._emitters[name] = em
            self._wire[name] = bytearray()
        return em

    def _advance(self, name: str) -> None:
        """Push not-yet-segmented values through the channel's carry state
        and encode the newly finalized segments onto the wire."""
        from repro.core import jax_pla
        if len(self.buffers[name]) < 2:
            # Hold back until the grid spacing is known (the emitter needs
            # dt); a 1-point window falls back to the batch path at flush.
            return
        done = self._stepped.get(name, 0)
        vals = self.buffers[name][done:]
        if not vals:
            return
        st = self._states.get(name)
        if st is None:
            st = jax_pla.init_state(
                self.method, 1, self.eps,
                max_run=PROTOCOL_CAPS["singlestreamv"])
        y = np.asarray(vals, np.float32)[None]
        st, out = jax_pla.step_chunk(st, y)
        self._states[name] = st
        self._stepped[name] = len(self.buffers[name])
        # Wire-ready watermark: for the deferred methods (continuous /
        # mixed) this lags the consumed count by the unresolved tail; the
        # emitter buffers the early values until their events release.
        self._released[name] = int(st.emitted)
        em = self._emitter(name)
        self._wire[name] += em.step_chunk(
            out, np.asarray(vals, np.float64)[None])[0]

    def _streaming_blob(self, name: str) -> Optional[bytes]:
        """Close the channel's run and return the window's wire bytes."""
        from repro.core import jax_pla
        if self._irregular.pop(name, False):
            return None
        self._advance(name)
        st = self._states.pop(name, None)
        if st is None:  # nothing ever advanced (empty window)
            return None
        em = self._emitters.pop(name)
        wire = self._wire.pop(name)
        st, out_f = jax_pla.flush(st)
        wire += em.step_chunk(out_f)[0]
        wire += em.flush()[0]
        self._released[name] = int(st.emitted)
        return bytes(wire)

    def lag(self, name: str) -> int:
        """Points of channel ``name`` not yet wire-ready (appended minus
        the released-column watermark).  For the deferred methods this
        includes the paper's extra segment of latency; the periodic flush
        always drains it to the window boundary."""
        return len(self.buffers.get(name, ())) - self._released.get(name, 0)

    # ---- flush -----------------------------------------------------------

    def _flush_channel(self, name: str) -> bytes:
        ys = np.asarray(self.buffers[name])
        ts = np.asarray(self.steps[name], dtype=float)
        blob = self._streaming_blob(name) if self.streaming else None
        self.buffers[name] = []
        self.steps[name] = []
        self._stepped[name] = 0
        self._released[name] = 0
        if blob is None:
            cap = PROTOCOL_CAPS["singlestreamv"]
            out = METHODS[self.method](ts, ys, self.eps, max_run=cap)
            blob = encode_singlestreamv(PROTOCOLS["singlestreamv"](
                out, ts, ys))
        self.sent_bytes += len(blob)
        self.raw_bytes += 8 * len(ys)
        # Track the worst reconstruction error actually incurred, measured
        # off the wire (decode of the very bytes that were "sent").
        recon = np.asarray(decode_singlestreamv(blob, ts))
        self.max_err_seen = max(self.max_err_seen,
                                float(np.abs(recon - ys).max()))
        return blob

    def flush_all(self) -> bytes:
        names = [n for n, b in self.buffers.items() if b]
        return b"".join(self._flush_channel(n) for n in names)

    @property
    def ratio(self) -> float:
        return self.sent_bytes / self.raw_bytes if self.raw_bytes else 0.0
