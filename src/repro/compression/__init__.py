"""PLA stream compression as first-class framework features.

- :mod:`grad`      — error-feedback PLA-compressed cross-pod gradient
  reduction (paper scenario 1: fewer bytes over the slow link).
- :mod:`kv_cache`  — eps-bounded PLA compression of cold KV-cache blocks
  (paper scenario 2: datacenter storage reduction).
- :mod:`telemetry` — host-side metric streams compressed with the paper's
  lowest-latency protocol (SingleStreamV).
- :mod:`ckpt`      — byte-level PLA compression of smooth checkpoint
  tensors (optimizer second moments, EMAs).
"""

from .grad import (GradCompressionConfig, init_error_feedback,
                   pla_compress_leaf, pla_decompress_leaf,
                   pod_compressed_mean, compression_report)
from .kv_cache import PLAKVConfig, compress_kv_block, decompress_kv_block, \
    kv_compression_stats
from .telemetry import TelemetryCompressor
from .ckpt import encode_array, decode_array

__all__ = [
    "GradCompressionConfig", "init_error_feedback", "pla_compress_leaf",
    "pla_decompress_leaf", "pod_compressed_mean", "compression_report",
    "PLAKVConfig", "compress_kv_block", "decompress_kv_block",
    "kv_compression_stats", "TelemetryCompressor", "encode_array",
    "decode_array",
]
