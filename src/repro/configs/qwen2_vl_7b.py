"""Qwen2-VL-7B — M-RoPE decoder backbone [arXiv:2409.12191; hf].

Vision tower is a STUB per brief: input_specs feeds precomputed patch
embeddings added at image-token positions plus the (t, h, w) M-RoPE
position ids.  mrope_sections (16, 24, 24) over head_dim/2 = 64.
"""

from repro.models.base import ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab=152064, act="silu", rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke", family="vlm",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab=512, act="silu", mrope_sections=(4, 6, 6),
)
