"""RecurrentGemma-2B (Griffin) — RG-LRU + local attention, 1 attn : 2 rec
[arXiv:2402.19427; hf].

26 layers = 8 x (rec, rec, attn) superblocks + 2 trailing recurrent.
Local attention window 2048; MQA (kv=1), head_dim 256.
PLA KV compression applies only to the (bounded) local-attention windows
(DESIGN.md §Arch-applicability).
"""

from repro.models.base import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab=256000, act="gelu", attn_window=2048,
    hybrid_period=3, rnn_width=2560, conv_width=4, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke", family="hybrid",
    n_layers=5, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
    d_ff=256, vocab=512, act="gelu", attn_window=32,
    hybrid_period=3, rnn_width=128, conv_width=4, tie_embeddings=True,
)
