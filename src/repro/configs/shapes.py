"""Assigned input shapes x applicability rules (brief: 40 cells).

=============  ========== ============ =================
shape          seq_len     global_batch  lowers
=============  ========== ============ =================
train_4k       4,096       256          train_step
prefill_32k    32,768      32           prefill (train fwd machinery)
decode_32k     32,768      128          serve_step (1 token, 32k cache)
long_500k      524,288     1            serve_step (1 token, 500k context)
=============  ========== ============ =================

``long_500k`` needs sub-quadratic attention: it runs only for the SSM
(mamba2) and hybrid (recurrentgemma, local-window attention) families; the
8 pure full-attention archs skip it (recorded, per the brief).  Whisper is
encoder-decoder (it has a decoder) so decode shapes run against the
decoder with the stub-encoded 1500-frame source.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

from repro.models.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, ("full quadratic attention at 524k tokens — skipped "
                       "per brief (sub-quadratic archs only)")
    return True, ""


def cells(cfg: ModelConfig) -> List[str]:
    return [s for s in SHAPES if applicable(cfg, s)[0]]
