"""Architecture registry: 10 assigned archs + the paper's own config.

Each ``<id>.py`` exports ``FULL`` (the exact published config) and
``SMOKE`` (a reduced same-family config for CPU tests).  Shapes and
skip rules live in :mod:`repro.configs.shapes`.
"""

from importlib import import_module
from typing import Dict

from repro.models.base import ModelConfig

ARCHS = (
    "yi_6b", "gemma_2b", "yi_9b", "granite_3_2b", "recurrentgemma_2b",
    "mamba2_780m", "llama4_maverick", "olmoe_1b_7b", "whisper_base",
    "qwen2_vl_7b",
)

# canonical --arch ids (dashes) -> module names
ALIASES = {
    "yi-6b": "yi_6b",
    "gemma-2b": "gemma_2b",
    "yi-9b": "yi_9b",
    "granite-3-2b": "granite_3_2b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "mamba2-780m": "mamba2_780m",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "whisper-base": "whisper_base",
    "qwen2-vl-7b": "qwen2_vl_7b",
}


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod_name = ALIASES.get(name, name.replace("-", "_"))
    mod = import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE if smoke else mod.FULL


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCHS}
