"""Yi-6B — llama-arch dense GQA [arXiv:2403.04652; hf]."""

from repro.models.base import ModelConfig

FULL = ModelConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab=64000, act="silu", rope_theta=5_000_000.0,
)

SMOKE = ModelConfig(
    name="yi-6b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=344, vocab=512, act="silu",
)
