"""Mamba2-780m — SSD (state-space duality), attention-free
[arXiv:2405.21060].

d_inner = 2 * 1536 = 3072; 48 heads of dim 64; state N = 128; the
paper's-technique note: no KV cache exists, so PLA KV compression is
inapplicable (constant-size state) — recorded in DESIGN.md.
"""

from repro.models.base import ModelConfig

FULL = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, vocab=50280,
    n_heads=1, n_kv_heads=1, d_ff=0,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    conv_width=4, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-780m-smoke", family="ssm",
    n_layers=3, d_model=128, vocab=512,
    n_heads=1, n_kv_heads=1, d_ff=0,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=32,
    conv_width=4, tie_embeddings=True,
)
