"""Whisper-base — encoder-decoder audio backbone [arXiv:2212.04356].

Conv/mel frontend is a STUB per brief: input_specs feeds precomputed
(B, 1500, 512) frame embeddings.  6 encoder + 6 decoder layers, MHA.
"""

from repro.models.base import ModelConfig

FULL = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    head_dim=64, d_ff=2048, vocab=51865, act="gelu", enc_seq=1500,
)

SMOKE = ModelConfig(
    name="whisper-base-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    head_dim=32, d_ff=256, vocab=512, act="gelu", enc_seq=64,
)
