"""OLMoE-1B-7B — 64 experts, top-8, every layer MoE [arXiv:2409.02060; hf]."""

from repro.models.base import ModelConfig

FULL = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, d_ff_expert=1024, vocab=50304, act="silu",
    n_experts=64, top_k=8, moe_interleave=1, shared_expert=False,
    capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="olmoe-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=128, d_ff_expert=128, vocab=512, act="silu",
    n_experts=8, top_k=4, moe_interleave=1,
)
