"""Granite-3.0-2B — deep-narrow dense GQA
[hf:ibm-granite/granite-3.0-2b-base]."""

from repro.models.base import ModelConfig

FULL = ModelConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab=49155, act="silu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-3-2b-smoke", family="dense",
    n_layers=3, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab=512, act="silu", tie_embeddings=True,
)
