"""Llama-4-Maverick-400B-A17B — interleaved MoE, 128 experts top-1
[hf:meta-llama/Llama-4-*; unverified].

Per the HF config family: every 2nd layer is MoE (128 routed experts,
top-1, expert d_ff 8192) with a shared expert; the dense layers use
d_ff_mlp = 16384.  ~400B total / ~17B active.  bf16 moments + f32 master
recommended on a single 256-chip pod (see configs note in DESIGN.md).
"""

from repro.models.base import ModelConfig

FULL = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=16384, d_ff_expert=8192, vocab=202048, act="silu",
    n_experts=128, top_k=1, moe_interleave=2, shared_expert=True,
    capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="llama4-maverick-smoke", family="moe",
    n_layers=4, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=256, d_ff_expert=128, vocab=512, act="silu",
    n_experts=8, top_k=1, moe_interleave=2, shared_expert=True,
)
