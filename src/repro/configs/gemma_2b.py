"""Gemma-2B — GeGLU, head_dim=256, MQA [arXiv:2403.08295; hf]."""

from repro.models.base import ModelConfig

FULL = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=256000, act="gelu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma-2b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
    d_ff=256, vocab=512, act="gelu", tie_embeddings=True,
)
