"""Production mesh construction.

A *function*, not a module-level constant: importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any jax
device initialization).
"""

from __future__ import annotations

import jax

from repro.compat import sharding as compat_sharding


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 chips per pod (v5e); two pods when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_sharding.make_mesh(shape, axes)


def mesh_axis_sizes(mesh: jax.sharding.Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
