"""Batched serving loop with streaming PLA KV-cache compression.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --prompt-len 128 --gen 32 [--pla-kv --kv-hot 64 --kv-chunk 32]

``--no-smoke`` disables the shrunk config (the old ``--smoke`` flag
defaulted on and could never be turned off from the CLI).

Fleet-serving mode (paper scenario 1, ROADMAP "Million-stream serving
front-end") drives the admission-controlled front-end instead of the KV
demo — churny synthetic sensors through :class:`repro.serving.ServeLoop`
with an optional fleet-wide egress budget:

    PYTHONPATH=src python -m repro.launch.serve --fleet \
        --fleet-streams 32 --fleet-ticks 60 --churn 0.1 \
        --budget-bytes-per-s 2000

Prefills a batch of synthetic prompts, then decodes.  With ``--pla-kv``,
KV tokens are compressed *as they cross the hot window* (paper scenario
2): every ``--kv-chunk`` prefill steps the newly cold token columns of
each layer are pushed through a :class:`StreamingKVCompressor`, which
segments them incrementally through the carry-state engine and pops a
finished :class:`CompressedKVBlock` every 256 tokens — no one-shot
re-compression loop at the end of prefill.  Decode then runs against the
reconstructed history, and the run reports storage savings plus the
worst K/V perturbation vs. the exact cache.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.compression.kv_cache import (PLAKVConfig, StreamingKVCompressor,
                                        compressed_block_stats,
                                        decompress_kv_block)
from repro.configs import ALIASES, get_config
from repro.launch.specs import demo_batch
from repro.models.zoo import build_model


def _push_cold(comps, blocks, cache, lo: int, hi: int) -> None:
    """Feed cache token columns [lo, hi) of every layer to its compressor."""
    for layer, comp in enumerate(comps):
        blocks[layer].extend(comp.push(cache.k[layer, :, lo:hi],
                                       cache.v[layer, :, lo:hi]))


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    # BooleanOptionalAction so --no-smoke actually exists: the old
    # ``action="store_true", default=True`` spelling made smoke mode
    # impossible to disable from the CLI.
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="shrunk model config (use --no-smoke for full)")
    ap.add_argument("--arch", default="yi-6b", choices=list(ALIASES))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--pla-kv", action="store_true")
    ap.add_argument("--kv-eps", type=float, default=0.1)
    ap.add_argument("--kv-hot", type=int, default=64,
                    help="hot window: most recent tokens kept raw")
    ap.add_argument("--kv-chunk", type=int, default=32,
                    help="push cold tokens to the compressor every N steps")
    # Fleet-serving mode (repro.serving).
    ap.add_argument("--fleet", action="store_true",
                    help="serve a churny synthetic sensor fleet instead "
                         "of the KV demo")
    ap.add_argument("--fleet-streams", type=int, default=32,
                    help="live streams held in the slot plane")
    ap.add_argument("--fleet-capacity", type=int, default=0,
                    help="slot capacity (0: 2x the live streams)")
    ap.add_argument("--fleet-ticks", type=int, default=60)
    ap.add_argument("--tick-width", type=int, default=64)
    ap.add_argument("--churn", type=float, default=0.1,
                    help="fraction of live streams replaced per tick")
    ap.add_argument("--method", default="linear")
    ap.add_argument("--protocol", default="singlestream")
    ap.add_argument("--eps", type=float, default=0.5)
    ap.add_argument("--budget-bytes-per-s", type=float, default=0.0,
                    help="fleet egress budget (0: fixed eps, no "
                         "controller)")
    return ap


def serve_fleet(args) -> None:
    """Churny synthetic fleet through the admission-controlled loop."""
    import numpy as np

    from repro.serving import GlobalEpsBudget, ServeLoop, SlotManager

    rng = np.random.default_rng(0)
    cap = args.fleet_capacity or 2 * args.fleet_streams
    budget = None
    if args.budget_bytes_per_s > 0:
        budget = GlobalEpsBudget(args.budget_bytes_per_s,
                                 sample_hz=float(args.tick_width))
    mgr = SlotManager(args.method, args.protocol, capacity=cap,
                      eps0=args.eps)
    loop = ServeLoop(mgr, tick_width=args.tick_width,
                     queue_cap=8 * args.tick_width, budget=budget)

    def fresh(name):
        loop.admit(name, eps=args.eps)

    n_admitted = 0
    live = []
    for _ in range(args.fleet_streams):
        fresh(f"sensor-{n_admitted}")
        live.append(f"sensor-{n_admitted}")
        n_admitted += 1
    t0 = time.time()
    total_bytes = total_points = 0
    for k in range(args.fleet_ticks):
        # churn: replace a fraction of the fleet, out of phase
        for _ in range(int(len(live) * args.churn)):
            gone = live.pop(int(rng.integers(len(live))))
            rep = loop.evict(gone)
            total_bytes += len(rep.tail) \
                + sum(len(b) for _, _, b in rep.wire)
            fresh(f"sensor-{n_admitted}")
            live.append(f"sensor-{n_admitted}")
            n_admitted += 1
        for name in live:
            loop.offer(name, rng.normal(0, 1, args.tick_width)
                       .astype(np.float32).cumsum())
        rep = loop.tick()
        total_bytes += rep.nbytes
        total_points += rep.consumed
        if k % 10 == 0 or k == args.fleet_ticks - 1:
            pool = (f" pool={rep.budget_pool:.0f}B"
                    if rep.budget_pool is not None else "")
            print(f"tick {rep.tick:4d}: live={rep.live} "
                  f"consumed={rep.consumed} bytes={rep.nbytes} "
                  f"eps=[{rep.eps_lo:.3g}, {rep.eps_hi:.3g}]"
                  f"{pool} shed={rep.shed_total}")
    dt_s = time.time() - t0
    print(f"served {total_points} points / {total_bytes} wire bytes "
          f"across {n_admitted} stream admissions in {dt_s:.2f}s "
          f"({total_points / max(dt_s, 1e-9):,.0f} pts/s)")


def main(argv=None):
    args = build_parser().parse_args(argv)
    if args.fleet:
        serve_fleet(args)
        return

    cfg = get_config(args.arch, smoke=args.smoke)
    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    batch = demo_batch(cfg, B=args.batch, T=args.prompt_len, key=key)
    max_len = args.prompt_len + args.gen
    cache = api.make_cache(params, batch, max_len)

    pla_on = args.pla_kv and hasattr(cache, "k")
    kcfg = PLAKVConfig(block=256, eps=args.kv_eps)
    if pla_on:
        n_layers = cache.k.shape[0]
        comps = [StreamingKVCompressor(kcfg) for _ in range(n_layers)]
        blocks = [[] for _ in range(n_layers)]
        pushed = 0

    decode = jax.jit(lambda p, t, c: api.decode(p, t, c))
    t0 = time.time()
    for i in range(args.prompt_len):
        logits, cache = decode(params, batch["tokens"][:, i:i + 1], cache)
        if pla_on:
            cold_end = i + 1 - args.kv_hot
            if cold_end - pushed >= args.kv_chunk:
                _push_cold(comps, blocks, cache, pushed, cold_end)
                pushed = cold_end
    prefill_s = time.time() - t0

    if pla_on:
        # Tokens that crossed the hot window by the end of prefill.
        cold_end = max(args.prompt_len - args.kv_hot, 0)
        if cold_end > pushed:
            _push_cold(comps, blocks, cache, pushed, cold_end)
            pushed = cold_end
        n_blocks = len(blocks[0]) if blocks else 0
        if n_blocks:
            tot_raw = tot_comp = 0
            max_err = 0.0
            kd_layers, vd_layers = [], []
            for layer, layer_blocks in enumerate(blocks):
                kds, vds = [], []
                for b, blk in enumerate(layer_blocks):
                    lo, hi = b * kcfg.block, (b + 1) * kcfg.block
                    st = compressed_block_stats(blk, kcfg)
                    tot_raw += st["raw_bytes"]
                    tot_comp += st["compressed_bytes"]
                    kd, vd = decompress_kv_block(blk, kcfg)
                    max_err = max(
                        max_err,
                        float(jnp.abs(kd - cache.k[layer, :, lo:hi]
                                      .astype(jnp.float32)).max()),
                        float(jnp.abs(vd - cache.v[layer, :, lo:hi]
                                      .astype(jnp.float32)).max()))
                    kds.append(kd)
                    vds.append(vd)
                kd_layers.append(jnp.concatenate(kds, axis=1))
                vd_layers.append(jnp.concatenate(vds, axis=1))
            # One scatter per tensor: .at[].set on the full (L,B,T,KH,hd)
            # cache copies it whole, so per-block writes would be O(L*B_n)
            # full-cache copies.
            hi = n_blocks * kcfg.block
            cache = type(cache)(
                cache.k.at[:, :, :hi].set(
                    jnp.stack(kd_layers).astype(cache.k.dtype)),
                cache.v.at[:, :, :hi].set(
                    jnp.stack(vd_layers).astype(cache.v.dtype)),
                cache.length)
            print(f"PLA KV (streaming): {n_blocks} cold block(s)/layer, "
                  f"{tot_comp} vs {tot_raw} raw bytes "
                  f"({tot_comp/tot_raw:.3f}x) at eps={kcfg.eps}, "
                  f"max |err|={max_err:.3g}; "
                  f"{comps[0].pending_tokens} tokens pending")
        else:
            print(f"PLA KV (streaming): no block completed "
                  f"(cold tokens={pushed} < block={kcfg.block}); "
                  f"{comps[0].pending_tokens} tokens pending")

    tok = batch["tokens"][:, -1:]
    t0 = time.time()
    out_tokens = []
    for _ in range(args.gen):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(tok)
    gen_s = time.time() - t0
    toks = jnp.concatenate(out_tokens, axis=1)
    print(f"prefill {args.prompt_len} toks x{args.batch}: {prefill_s:.2f}s "
          f"| decode {args.gen} toks: {gen_s:.2f}s "
          f"({args.gen*args.batch/gen_s:.1f} tok/s)")
    print("sample:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
