"""Batched serving loop with optional PLA KV-cache compression.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --prompt-len 128 --gen 32 [--pla-kv]

Prefills a batch of synthetic prompts, then decodes; with ``--pla-kv``,
cold 256-token KV blocks are PLA-compressed (paper scenario 2) and decode
runs against the reconstructed history, reporting storage savings and the
logit perturbation vs. the exact cache.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.compression.kv_cache import (PLAKVConfig, compress_kv_block,
                                        decompress_kv_block,
                                        kv_compression_stats)
from repro.configs import ALIASES, get_config
from repro.launch.specs import demo_batch
from repro.models.zoo import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=list(ALIASES))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--pla-kv", action="store_true")
    ap.add_argument("--kv-eps", type=float, default=0.1)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    batch = demo_batch(cfg, B=args.batch, T=args.prompt_len, key=key)
    max_len = args.prompt_len + args.gen
    cache = api.make_cache(params, batch, max_len)

    decode = jax.jit(lambda p, t, c: api.decode(p, t, c))
    t0 = time.time()
    for i in range(args.prompt_len):
        logits, cache = decode(params, batch["tokens"][:, i:i + 1], cache)
    prefill_s = time.time() - t0

    if args.pla_kv and hasattr(cache, "k") and args.prompt_len >= 256:
        kcfg = PLAKVConfig(block=256, eps=args.kv_eps)
        tot_raw = tot_comp = 0
        kd_all, vd_all = [], []
        for layer in range(cache.k.shape[0]):
            kb, vb = cache.k[layer, :, :256], cache.v[layer, :, :256]
            st = kv_compression_stats(kb, vb, kcfg)
            tot_raw += st["raw_bytes"]
            tot_comp += st["compressed_bytes"]
            blk = compress_kv_block(kb, vb, kcfg)
            kd, vd = decompress_kv_block(blk, kcfg)
            kd_all.append(kd)
            vd_all.append(vd)
        cache = type(cache)(
            cache.k.at[:, :, :256].set(
                jnp.stack(kd_all).astype(cache.k.dtype)),
            cache.v.at[:, :, :256].set(
                jnp.stack(vd_all).astype(cache.v.dtype)),
            cache.length)
        print(f"PLA KV: {tot_comp} vs {tot_raw} raw bytes "
              f"({tot_comp/tot_raw:.3f}x) at eps={kcfg.eps}")

    tok = batch["tokens"][:, -1:]
    t0 = time.time()
    out_tokens = []
    for _ in range(args.gen):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(tok)
    gen_s = time.time() - t0
    toks = jnp.concatenate(out_tokens, axis=1)
    print(f"prefill {args.prompt_len} toks x{args.batch}: {prefill_s:.2f}s "
          f"| decode {args.gen} toks: {gen_s:.2f}s "
          f"({args.gen*args.batch/gen_s:.1f} tok/s)")
    print("sample:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
