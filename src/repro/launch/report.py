"""Generate EXPERIMENTS.md sections from dry-run/roofline JSON artifacts.

    PYTHONPATH=src python -m repro.launch.report > EXPERIMENTS.generated.md
"""

from __future__ import annotations

import glob
import json
import os

HERE = os.path.join(os.path.dirname(__file__), "..", "..", "..")
ROOF = os.path.join(HERE, "experiments", "roofline")
DRY = os.path.join(HERE, "experiments", "dryrun")

ARCH_ORDER = ["yi_6b", "gemma_2b", "yi_9b", "granite_3_2b",
              "recurrentgemma_2b", "mamba2_780m", "llama4_maverick",
              "olmoe_1b_7b", "whisper_base", "qwen2_vl_7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(pattern):
    out = {}
    for f in glob.glob(pattern):
        with open(f) as fh:
            rec = json.load(fh)
        out[(rec.get("arch"), rec.get("shape"), rec.get("mesh"))] = rec
    return out


def _fmt_bytes(b):
    if b is None:
        return "—"
    return f"{b/2**30:.2f}"


def dryrun_table() -> str:
    recs = _load(os.path.join(DRY, "*.json"))
    lines = ["### §Dry-run — every (arch × shape) × {16×16, 2×16×16}",
             "",
             "| arch | shape | mesh | status | params | GiB/dev | fits 16G |"
             " compile s |",
             "|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("16x16", "2x16x16"):
                r = recs.get((arch, shape, mesh))
                if r is None:
                    continue
                if r["status"] == "skipped":
                    lines.append(f"| {arch} | {shape} | {mesh} | skipped |"
                                 f" — | — | — | — |")
                    continue
                if r["status"] != "ok":
                    lines.append(f"| {arch} | {shape} | {mesh} | "
                                 f"**FAILED** | — | — | — | — |")
                    continue
                lines.append(
                    f"| {arch} | {shape} | {mesh} | ok |"
                    f" {r['n_params']/1e9:.2f}B |"
                    f" {_fmt_bytes(r['resident_bytes_per_device'])} |"
                    f" {'yes' if r['fits_hbm'] else 'NO*'} |"
                    f" {r.get('compile_s', 0):.0f} |")
    return "\n".join(lines)


def roofline_table() -> str:
    recs = _load(os.path.join(ROOF, "*.json"))
    lines = ["### §Roofline — single-pod (256 × v5e) baseline, per cell",
             "",
             "compute_s = HLO_FLOPs/(chip·197TF); memory_s = HLO_bytes/"
             "(chip·819GB/s); collective_s = ring-moved bytes/(chip·50GB/s)."
             " All from the loop-aware HLO pass (launch/hlo_stats.py).",
             "",
             "| arch | shape | compute ms | memory ms | collective ms |"
             " dominant | 6ND/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = None
            for key, rec in recs.items():
                if key[0] == arch and key[1] == shape and \
                        rec.get("mesh") == "16x16":
                    r = rec
            if r is None or r.get("status") != "ok":
                continue
            lines.append(
                f"| {arch} | {shape} | {r['compute_s']*1e3:.1f} |"
                f" {r['memory_s']*1e3:.1f} |"
                f" {r['collective_ring_s']*1e3:.1f} | {r['dominant']} |"
                f" {r['useful_flop_ratio']:.2f} |"
                f" {r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def collective_breakdown() -> str:
    recs = _load(os.path.join(ROOF, "*.json"))
    lines = ["### Collective traffic by mesh axis (ring-moved bytes/device)",
             "",
             "| arch | shape | model | data | pod | #ops |",
             "|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = None
            for key, rec in recs.items():
                if key[0] == arch and key[1] == shape:
                    r = rec
            if r is None or r.get("status") != "ok":
                continue
            ax = r.get("collective_by_axis", {})

            def g(a):
                v = ax.get(a, 0)
                return f"{v/2**30:.2f}G" if v else "—"
            lines.append(f"| {arch} | {shape} | {g('model')} | {g('data')} |"
                         f" {g('pod')} | {r.get('n_collectives', 0)} |")
    return "\n".join(lines)


def main():
    print(dryrun_table())
    print()
    print(roofline_table())
    print()
    print(collective_breakdown())


if __name__ == "__main__":
    main()
