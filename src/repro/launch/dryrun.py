"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: parameters
and inputs are ShapeDtypeStructs, ``jit(...).lower(...).compile()`` must
succeed on the 256-chip single-pod mesh and the 512-chip two-pod mesh, and
``memory_analysis`` must fit the 16 GiB/chip HBM of a v5e.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b \
        --shape train_4k --mesh multi                           # one cell
Outputs one JSON per cell under experiments/dryrun/.
"""

# The dry-run needs 512 placeholder devices; jax locks the device count at
# first init, so this MUST precede every other import (including repro.*).
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp                                     # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.compat import sharding as compat_sharding        # noqa: E402
from repro.configs import ARCHS, ALIASES, get_config        # noqa: E402
from repro.configs.shapes import SHAPES, applicable         # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes  # noqa: E402
from repro.launch.specs import (batch_shard_specs, cache_shard_specs,  # noqa: E402
                                eval_cache, eval_params, input_specs,
                                make_prefill_step, make_serve_step, named)
from repro.models.zoo import build_model                    # noqa: E402
from repro.optimizer import AdamWConfig, adamw_init         # noqa: E402
from repro.runtime.train_loop import TrainConfig, make_train_step  # noqa: E402
from repro.sharding import param_specs                      # noqa: E402

HBM_BYTES = 16 * 1024 ** 3  # v5e

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _param_count(avals) -> int:
    import math
    return sum(math.prod(l.shape) for l in jax.tree.leaves(avals))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               grad_mode: str = "baseline", save_text: bool = False,
               return_compiled: bool = False, step_overrides=None):
    """Lower+compile one cell; returns the result record.

    ``step_overrides``: optional dict tweaking the step construction
    (used by the perf hillclimb): {"grad_accum": int, "remat": bool}.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh_axis_sizes(mesh)
    if (step_overrides or {}).get("remat") is not None:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, remat=step_overrides["remat"])
    if shape.kind != "train":
        # Serving deployments load bf16 weights (halves HBM; the f32
        # master copies live only in the training job's optimizer).
        import dataclasses as _dc
        cfg = _dc.replace(cfg, param_dtype="bfloat16")
    api = build_model(cfg)
    params_avals = eval_params(api)
    n_params = _param_count(params_avals)
    # Very large models: bf16 moments + cross-pod ZeRO (DESIGN.md note).
    moment_dtype = "bfloat16" if n_params > 100e9 else "float32"
    if shape.kind == "train":
        fsdp = ("pod", "data") if (n_params > 100e9 and multi_pod) else True
    else:
        # Serving: ZeRO param-sharding would re-all-gather every layer's
        # weights per token batch over the data axis (§Perf P9); bf16
        # weights sharded model-only fit every arch except llama4, which
        # keeps data-sharding out of memory necessity.
        fsdp = n_params > 100e9
    fsdp = (step_overrides or {}).get("fsdp", fsdp)
    strategy = (step_overrides or {}).get("strategy", "tp")
    if strategy == "fsdp":
        from repro.models.base import set_batch_axes
        set_batch_axes(("pod", "data", "model"))
    pspecs = param_specs(params_avals, cfg, axes, fsdp=fsdp,
                         strategy=strategy)
    psh = named(mesh, pspecs)

    t0 = time.time()
    with compat_sharding.use_mesh(mesh):
        if shape.kind == "train":
            # grad_accum=8: microbatching bounds remat-saved activations
            # (measured: yi-6b@4k 49.5 GiB -> 6.4 GiB/device, §Perf).
            accum = (step_overrides or {}).get("grad_accum", 8)
            tcfg = TrainConfig(
                grad_mode=grad_mode, grad_accum=accum,
                cast_params_once=(step_overrides or {}).get(
                    "cast_once", True),
                adamw=AdamWConfig(moment_dtype=moment_dtype))
            step = make_train_step(api, tcfg, mesh)
            batch = input_specs(cfg, shape)
            opt_avals = jax.eval_shape(
                lambda p: adamw_init(p, tcfg.adamw), params_avals)
            if grad_mode == "pla":
                ef_avals = jax.tree.map(
                    lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32),
                    params_avals)
                ef_sh = psh
            else:
                ef_avals = jax.ShapeDtypeStruct((), jnp.float32)
                ef_sh = NamedSharding(mesh, P())
            opt_sh = type(opt_avals)(
                step=NamedSharding(mesh, P()), m=psh, v=psh)
            bsh = named(mesh, batch_shard_specs(batch, axes, strategy))
            step_idx = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(
                step,
                in_shardings=(psh, opt_sh, ef_sh, bsh,
                              NamedSharding(mesh, P())),
                out_shardings=(psh, opt_sh, ef_sh, None),
                donate_argnums=(0, 1, 2))
            lowered = jitted.lower(params_avals, opt_avals, ef_avals,
                                   batch, step_idx)
        elif shape.kind == "prefill":
            fn = make_prefill_step(api)
            batch = input_specs(cfg, shape)
            bsh = named(mesh, batch_shard_specs(batch, axes, strategy))
            jitted = jax.jit(fn, in_shardings=(psh, bsh))
            lowered = jitted.lower(params_avals, batch)
        else:  # decode
            fn = make_serve_step(api)
            batch = input_specs(cfg, shape)
            cache_avals = eval_cache(api, batch, shape.seq_len)
            csh = named(mesh,
                        cache_shard_specs(cfg, cache_avals, axes))
            bsh = named(mesh, batch_shard_specs(batch, axes, strategy))
            jitted = jax.jit(fn, in_shardings=(psh, bsh["tokens"], csh),
                             out_shardings=(None, csh),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_avals, batch["tokens"],
                                   cache_avals)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    mem_rec = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
    }
    # total resident = args (+aliased outputs counted once) + temps
    resident = (mem_rec["argument_bytes"] or 0) + (mem_rec["temp_bytes"] or 0)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "grad_mode": grad_mode if shape.kind == "train" else None,
        "status": "ok",
        "n_params": n_params,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem_rec,
        "resident_bytes_per_device": resident,
        "fits_hbm": bool(resident <= HBM_BYTES),
        "flops": cost.get("flops") if isinstance(cost, dict) else None,
        "bytes_accessed": cost.get("bytes accessed")
        if isinstance(cost, dict) else None,
    }
    if strategy == "fsdp":  # restore the default for subsequent cells
        from repro.models.base import set_batch_axes
        set_batch_axes(("pod", "data"))
    if save_text:
        os.makedirs(OUT_DIR, exist_ok=True)
        tag = f"{arch}_{shape_name}_{rec['mesh']}"
        with open(os.path.join(OUT_DIR, tag + ".hlo.txt"), "w") as f:
            f.write(compiled.as_text())
    if return_compiled:
        return rec, compiled
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    choices=["all"] + list(ALIASES) + list(ARCHS))
    ap.add_argument("--shape", default="all",
                    choices=["all"] + list(SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--grad-mode", default="baseline",
                    choices=["baseline", "pla"])
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else \
        [ALIASES.get(args.arch, args.arch)]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(OUT_DIR, exist_ok=True)
    results = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}_{shape}_{'2x16x16' if multi else '16x16'}"
                try:
                    rec = lower_cell(arch, shape, multi, args.grad_mode,
                                     save_text=args.save_hlo)
                except Exception as e:  # a failure here is a system bug
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi else "16x16",
                           "status": "FAILED", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                results.append(rec)
                with open(os.path.join(OUT_DIR, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=2)
                status = rec["status"]
                extra = "" if status != "ok" else (
                    f" params={rec['n_params']/1e9:.2f}B "
                    f"resident={rec['resident_bytes_per_device']/2**30:.2f}GiB "
                    f"fits={rec['fits_hbm']} compile={rec['compile_s']}s")
                print(f"[{status:7}] {tag}{extra}", flush=True)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "FAILED" for r in results)
    print(f"\n{n_ok} ok / {n_skip} skipped / {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
