"""Roofline analysis from compiled dry-run artifacts (deliverable g).

No hardware here (CPU-only container), so instead of wall-clock MFU we
derive the three roofline *terms* per (arch x shape) on the single-pod
mesh, from the per-device partitioned HLO:

  compute_s    = device_flops / PEAK_FLOPS
  memory_s     = device_bytes_accessed / HBM_BW
  collective_s = device_collective_operand_bytes / LINK_BW

``cost_analysis()`` supplies flops / bytes-accessed of the per-device
module (loop bodies multiplied by trip counts).  Collective bytes come
from an HLO-text pass: for every all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute we reconstruct the *operand* bytes from
the printed output shape and the replica-group size, classify the mesh
axis by the device-id stride inside the groups, and also report a
ring-algorithm refinement (2(k-1)/k for all-reduce etc.).

MODEL_FLOPS uses the 6ND (train) / 2ND (inference) convention with
non-embedding (active, for MoE) parameters, so the ratio
MODEL_FLOPS / HLO_FLOPS exposes remat/dispatch overheads.
"""

# Must precede jax device init (see dryrun.py).
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse    # noqa: E402
import json        # noqa: E402
import re          # noqa: E402
from typing import Dict, List   # noqa: E402

import numpy as np              # noqa: E402

from repro.configs import ARCHS, ALIASES, get_config   # noqa: E402
from repro.configs.shapes import SHAPES, applicable    # noqa: E402
from repro.launch.dryrun import lower_cell, OUT_DIR    # noqa: E402

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # B/s
LINK_BW = 50e9             # B/s per ICI link

ROOF_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "experiments", "roofline")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "s64": 8, "u64": 8}

_COLL_RE = re.compile(
    r"(\w+\[[^\]]*\][^=]*?)?=\s*(?:\([^)]*\)\s*)?"
    r"(?:(\w[\w.\-]*)\s*=\s*)?", re.X)

_OP_LINE = re.compile(
    r"=\s*(?P<otype>\([^=]*?\)|[a-z0-9]+\[[^\]]*\]\S*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")

_GROUPS = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]"
                          r"(?:T\(([\d,]+)\))?")
_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def _group_info(line: str):
    """(group_size, stride) from replica_groups (list or iota form)."""
    m = _GROUPS_IOTA.search(line)
    if m:
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = m.group(4)
        if perm:
            # iota [G,S]<=[dims]T(perm): stride = product of dims after
            # the permuted leading dims; approximate: stride of the last
            # permuted axis
            p = [int(x) for x in perm.split(",")]
            tail = 1
            for ax in range(p[-1] + 1, len(dims)):
                tail *= dims[ax]
            return group_size, tail
        return group_size, 1
    m = _GROUPS.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{}")
        ids = [int(x) for x in first.split(",") if x.strip()]
        if len(ids) >= 2:
            return len(ids), ids[1] - ids[0]
        return max(len(ids), 1), 1
    return 1, 1


def _axis_of(stride: int, group_size: int, multi_pod: bool) -> str:
    """Map (stride) to a mesh axis for meshes (pod=2, data=16, model=16)."""
    if stride == 1:
        return "model"
    if stride == 16:
        return "data"
    if stride == 256:
        return "pod"
    return f"stride{stride}"


def parse_collectives(hlo: str, multi_pod: bool) -> List[Dict]:
    """Per-collective records: op, operand bytes (per device), axis."""
    out = []
    for line in hlo.splitlines():
        m = _OP_LINE.search(line)
        if m is None:
            continue
        if "-done" in line.split("=", 1)[-1][:60]:
            continue
        op = m.group("op")
        out_bytes = _shape_bytes(m.group("otype"))
        k, stride = _group_info(line)
        if op == "all-gather":
            operand = out_bytes // max(k, 1)
        elif op == "reduce-scatter":
            operand = out_bytes * k
        else:
            operand = out_bytes
        # ring-algorithm bytes actually moved per device
        if op == "all-reduce":
            moved = 2 * operand * (k - 1) / max(k, 1)
        elif op in ("all-gather", "reduce-scatter"):
            moved = operand * (k - 1)  # per device receives (k-1) shards
        elif op == "all-to-all":
            moved = operand * (k - 1) / max(k, 1)
        else:  # collective-permute
            moved = operand
        out.append({"op": op, "operand_bytes": operand,
                    "moved_bytes": moved, "group": k,
                    "axis": _axis_of(stride, k, multi_pod)})
    return out


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    # non-embedding params; MoE: active experts only
    D, L = cfg.d_model, cfg.n_layers
    hd, H, KH = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    if cfg.family == "ssm":
        d_in = cfg.ssm_expand * D
        Nl = D * (2 * d_in + 2 * cfg.ssm_state
                  + d_in // cfg.ssm_head_dim) + d_in * D
    else:
        attn = D * hd * (H + 2 * KH) + H * hd * D
        if cfg.family == "moe":
            n_moe = L // cfg.moe_interleave
            n_dense = L - n_moe
            moe_ff = 3 * D * cfg.ffe * cfg.top_k \
                + (3 * D * cfg.ffe if cfg.shared_expert else 0)
            dense_ff = 3 * D * cfg.d_ff
            Nl = attn + (n_moe * moe_ff + n_dense * dense_ff) / L
        elif cfg.family == "hybrid":
            W = cfg.rnn_width or D
            n_att = L // cfg.hybrid_period
            rec = 3 * D * W + 2 * W * W
            Nl = (n_att * attn + (L - n_att) * rec) / L + 3 * D * cfg.d_ff
        else:
            Nl = attn + 3 * D * cfg.d_ff
    N = Nl * L
    if cfg.family == "encdec":
        N *= 2  # encoder + decoder stacks (cross-attn approx.)
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * N * tokens
    if shape.kind == "prefill":
        return 2.0 * N * tokens
    return 2.0 * N * shape.global_batch  # decode: one token per request


def analyze(arch: str, shape_name: str, multi_pod: bool = False,
            grad_mode: str = "baseline", step_overrides=None) -> Dict:
    cfg = get_config(arch)
    ok, why = applicable(cfg, shape_name)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                "status": "skipped", "reason": why}
    rec, compiled = lower_cell(arch, shape_name, multi_pod, grad_mode,
                               return_compiled=True,
                               step_overrides=step_overrides)
    hlo = compiled.as_text()
    # Loop-aware totals from the HLO itself: cost_analysis() on this
    # backend does NOT scale while-loop bodies by trip count (verified —
    # a 32-layer scan x8 accum shows ~256x fewer flops than 6ND), so we
    # parse known_trip_count and multiply (launch/hlo_stats.py).
    from repro.launch.hlo_stats import analyze_hlo
    stats = analyze_hlo(hlo)
    colls = stats["collectives"]
    dev_flops = stats["flops"]
    dev_bytes = stats["hbm_bytes"]
    coll_operand = sum(c["operand_bytes"] for c in colls)
    coll_moved = sum(c["moved_bytes"] for c in colls)
    by_axis: Dict[str, float] = {}
    for c in colls:
        by_axis[c["axis"]] = by_axis.get(c["axis"], 0) + c["moved_bytes"]
    by_op: Dict[str, float] = {}
    for c in colls:
        by_op[c["op"]] = by_op.get(c["op"], 0) + c["moved_bytes"]

    compute_s = dev_flops / PEAK_FLOPS
    memory_s = dev_bytes / HBM_BW
    coll_s = coll_operand / LINK_BW           # brief's primary formula
    coll_ring_s = coll_moved / LINK_BW        # ring refinement
    chips = 512 if multi_pod else 256
    mf = model_flops(arch, shape_name)
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", coll_ring_s), key=lambda t: t[1])[0]
    bound = max(compute_s, memory_s, coll_ring_s)
    out = {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "kind", "status",
                               "n_params", "compile_s",
                               "resident_bytes_per_device", "fits_hbm")},
        "device_flops": dev_flops,
        "device_bytes": dev_bytes,
        "collective_operand_bytes": coll_operand,
        "collective_moved_bytes": coll_moved,
        "collective_by_axis": by_axis,
        "collective_by_op": by_op,
        "n_collectives": len(colls),
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "collective_ring_s": coll_ring_s,
        "dominant": dominant,
        "model_flops_global": mf,
        "model_flops_per_device": mf / chips,
        "useful_flop_ratio": (mf / chips) / dev_flops if dev_flops else None,
        "roofline_fraction": ((mf / chips) / PEAK_FLOPS) / bound
        if bound > 0 else None,
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--grad-mode", default="baseline")
    args = ap.parse_args()
    archs = list(ARCHS) if args.arch == "all" else \
        [ALIASES.get(args.arch, args.arch)]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    os.makedirs(ROOF_DIR, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            tag = f"{arch}_{shape}_{args.mesh}_{args.grad_mode}"
            try:
                rec = analyze(arch, shape, args.mesh == "multi",
                              args.grad_mode)
            except Exception as e:
                import traceback
                rec = {"arch": arch, "shape": shape, "status": "FAILED",
                       "error": repr(e),
                       "trace": traceback.format_exc()[-1500:]}
            with open(os.path.join(ROOF_DIR, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=2)
            if rec["status"] == "ok":
                print(f"{tag}: compute={rec['compute_s']*1e3:.1f}ms "
                      f"memory={rec['memory_s']*1e3:.1f}ms "
                      f"coll(ring)={rec['collective_ring_s']*1e3:.1f}ms "
                      f"dominant={rec['dominant']} "
                      f"roofline_frac={rec['roofline_fraction']:.3f}",
                      flush=True)
            else:
                print(f"{tag}: {rec['status']} "
                      f"{rec.get('reason', rec.get('error', ''))}",
                      flush=True)


if __name__ == "__main__":
    main()
