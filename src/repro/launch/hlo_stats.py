"""Loop-aware FLOP / byte / collective totals from compiled HLO text.

``compiled.cost_analysis()`` on this backend reports while-loop bodies
*once* (verified: a 32-layer scan x 8-way accumulation shows ~256x fewer
flops than 6ND).  This module parses the post-optimization HLO instead:

1. split the module into named computations;
2. build the call multiplier map — while bodies multiply by their
   ``known_trip_count``, fusions/calls/reductions inherit the caller's
   multiplier;
3. total
   - flops: every ``dot`` op = 2 * prod(output dims) * K (K from the lhs
     contracting dims), times the multiplier;
   - hbm bytes: top-level op outputs x2 (read+write proxy; fusion
     internals excluded — post-fusion HLO keeps one output per fusion,
     which is exactly the HBM-traffic granularity);
   - collective operand bytes per op kind/axis, times multiplier.
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "s64": 8, "u64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z]\d+|pred|bf16)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\s*{\s*"n":\s*"?(\d+)'
                      r'|known_trip_count[^\d]{0,20}(\d+)')
_CALL_RE = re.compile(r"(?:calls=|to_apply=|body=|condition=|branch_computations={)"
                      r"%?([\w.\-]+)")
_DOT_RE = re.compile(
    r"=\s*(?:[a-z]\d+|bf16)\[([\d,]*)\][^\s]*\s+dot\(\s*"
    r"(?:(?:[a-z]\d+|bf16)\[([\d,]*)\][^%]*)?%([\w.\-]+)"
    r".*?lhs_contracting_dims={([\d,]*)}")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
                     r"((?:\([^)]*\))|(?:[a-z]\d+|bf16|pred)\[[\d,]*\]\S*)")
_COLL_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z]\d+\[[^\]]*\]\S*|bf16\[[^\]]*\]\S*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]"
                          r"(?:T\(([\d,]+)\))?")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _dims(s: str) -> List[int]:
    return [int(x) for x in s.split(",") if x] or [1]


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * b
    return total


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """Computation name -> body lines.

    Header lines look like ``[ENTRY] %name (args...) -> type {`` where the
    arg list may contain nested parens/braces (tuple types, layouts); we
    identify headers by shape (top level, '->', trailing '{') and take the
    name as the first %token.
    """
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped and \
                    not line.startswith((" ", "\t")):
                tok = stripped.split()[1] if stripped.startswith("ENTRY") \
                    else stripped.split()[0]
                name = tok.lstrip("%").split("(")[0].rstrip(",")
                if name:
                    cur = name
                    comps[cur] = []
            continue
        if stripped == "}" or stripped.startswith("} "):
            cur = None
            continue
        comps[cur].append(stripped)
    return comps


def _entry_name(hlo: str, comps: Dict[str, List[str]]) -> str:
    m = re.search(r"^ENTRY %?([\w.\-]+)", hlo, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: computation not referenced by any other
    referenced = set()
    for lines in comps.values():
        for ln in lines:
            for r in _CALL_RE.findall(ln):
                referenced.add(r)
    for name in comps:
        if name not in referenced:
            return name
    return next(iter(comps))


def multipliers(hlo: str, comps: Dict[str, List[str]]) -> Dict[str, float]:
    """Execution-count multiplier per computation."""
    entry = _entry_name(hlo, comps)
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    mult[entry] = 1.0
    # iterate to fixpoint (call graph is a DAG; few passes suffice)
    for _ in range(len(comps)):
        changed = False
        for name, lines in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for ln in lines:
                trip = None
                wm = _WHILE_RE.search(ln)
                if wm:
                    tm = _TRIP_RE.search(ln)
                    trip = int(tm.group(1) or tm.group(2)) if tm else 1
                    body = wm.group(1)
                    new = m * trip
                    if new > mult.get(body, 0.0):
                        mult[body] = new
                        changed = True
                    # condition runs trip+1 times; negligible, skip
                    continue
                for callee in _CALL_RE.findall(ln):
                    if callee in mult and m > mult.get(callee, 0.0):
                        mult[callee] = m
                        changed = True
        if not changed:
            break
    return mult


def _is_fusion_body(name: str) -> bool:
    return "fused_computation" in name or name.startswith("region_") is False \
        and "fused" in name


def analyze_hlo(hlo: str) -> Dict:
    comps = split_computations(hlo)
    mult = multipliers(hlo, comps)
    fusion_bodies = set()
    for lines in comps.values():
        for ln in lines:
            fm = re.search(r"fusion\(.*?calls=%?([\w.\-]+)", ln)
            if fm:
                fusion_bodies.add(fm.group(1))

    flops = 0.0
    hbm_bytes = 0.0
    colls: List[Dict] = []
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        top_level = name not in fusion_bodies
        # local def -> type map (operands are printed without types)
        defs: Dict[str, str] = {}
        for ln in lines:
            dd = _DEF_RE.match(ln)
            if dd:
                defs[dd.group(1)] = dd.group(2)
        for ln in lines:
            dm = _DOT_RE.search(ln)
            if dm:
                out_dims = _dims(dm.group(1))
                if dm.group(2):  # inline lhs type
                    lhs_dims = _dims(dm.group(2))
                else:            # look up the lhs operand's definition
                    lhs_t = defs.get(dm.group(3), "")
                    sm = _SHAPE_RE.search(lhs_t)
                    lhs_dims = _dims(sm.group(2)) if sm else [1]
                k = 1
                for ci in _dims(dm.group(4)):
                    if ci < len(lhs_dims):
                        k *= lhs_dims[ci]
                n_out = 1
                for d in out_dims:
                    n_out *= d
                flops += m * 2.0 * n_out * k
            if top_level and "=" in ln:
                # output bytes of data-moving top-level ops, x2 r+w proxy.
                # Skip ops with no data movement: tuple plumbing, casts of
                # layout metadata, parameters, while/conditional results
                # (their 'output' is the whole carried state).
                head = ln.split("=", 1)[1].strip()
                parts = head.split(" ", 1)
                opname = parts[1].split("(")[0].strip() if len(parts) > 1 \
                    else ""
                if opname not in ("get-tuple-element", "tuple", "parameter",
                                  "bitcast", "constant", "while",
                                  "conditional", "after-all",
                                  "opt-barrier") and opname:
                    hbm_bytes += m * 2.0 * _shape_bytes(parts[0])
            cm = _COLL_RE.search(ln)
            if cm and "-done" not in ln[:ln.find("(")]:
                out_b = _shape_bytes(cm.group(1))
                op = cm.group(2)
                k, stride = _group_info(ln)
                if op == "all-gather":
                    operand = out_b // max(k, 1)
                elif op == "reduce-scatter":
                    operand = out_b * k
                else:
                    operand = out_b
                if op == "all-reduce":
                    moved = 2 * operand * (k - 1) / max(k, 1)
                elif op in ("all-gather", "reduce-scatter"):
                    moved = operand * (k - 1)
                elif op == "all-to-all":
                    moved = operand * (k - 1) / max(k, 1)
                else:
                    moved = operand
                colls.append({"op": op, "operand_bytes": m * operand,
                              "moved_bytes": m * moved, "group": k,
                              "axis": _axis_of(stride)})
    return {"flops": flops, "hbm_bytes": hbm_bytes, "collectives": colls}


def _group_info(line: str) -> Tuple[int, int]:
    m = _GROUPS_IOTA.search(line)
    if m:
        group_size = int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = m.group(4)
        if perm:
            p = [int(x) for x in perm.split(",")]
            tail = 1
            for ax in range(p[-1] + 1, len(dims)):
                tail *= dims[ax]
            return group_size, tail
        return group_size, 1
    m = _GROUPS_LIST.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x.strip()]
        if len(ids) >= 2:
            return len(ids), ids[1] - ids[0]
        return max(len(ids), 1), 1
    return 1, 1


def _axis_of(stride: int) -> str:
    return {1: "model", 16: "data", 256: "pod"}.get(stride,
                                                    f"stride{stride}")
