"""Fleet training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b \
        --steps 100 [--smoke] [--grad-mode pla] [--mesh single|multi|host]

``--mesh host`` builds a mesh from the real local devices (CPU demo /
single TPU host); single/multi build the production meshes (requires the
matching device count — use the dry-run for topology-only checks).
``--smoke`` swaps in the reduced same-family config so the full driver
stack (data pipeline, telemetry compression, async checkpoints, PLA
gradient exchange) runs end-to-end on a laptop.
"""

import os
if os.environ.get("REPRO_FAKE_DEVICES"):  # optional topology emulation
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_FAKE_DEVICES"] + " "
                               + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402

import jax               # noqa: E402

from repro.compat import sharding as compat_sharding           # noqa: E402
from repro.compression.grad import GradCompressionConfig       # noqa: E402
from repro.compression.telemetry import TelemetryCompressor    # noqa: E402
from repro.configs import ALIASES, get_config                  # noqa: E402
from repro.configs.shapes import SHAPES                        # noqa: E402
from repro.data.pipeline import PipelineConfig, TokenPipeline  # noqa: E402
from repro.launch.mesh import make_production_mesh             # noqa: E402
from repro.models.zoo import build_model                       # noqa: E402
from repro.runtime.checkpoint import (CheckpointConfig,        # noqa: E402
                                      CheckpointManager)
from repro.runtime.train_loop import TrainConfig, run_train    # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=list(ALIASES))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=0, help="0 = shape default")
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--grad-mode", default="baseline",
                    choices=["baseline", "pla"])
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    api = build_model(cfg)
    shape = SHAPES["train_4k"]
    B = args.batch or (8 if args.smoke else shape.global_batch)
    T = args.seq or (128 if args.smoke else shape.seq_len)

    if args.mesh == "host":
        n = len(jax.devices())
        if args.grad_mode == "pla" and n >= 2:
            mesh = compat_sharding.make_mesh((2, n // 2), ("pod", "data"))
        elif n > 1:
            mesh = compat_sharding.make_mesh((n,), ("data",))
        else:
            mesh = None
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    pipe = TokenPipeline(PipelineConfig(vocab=cfg.vocab, global_batch=B,
                                        seq_len=T))
    ck = CheckpointManager(CheckpointConfig(directory=args.ckpt_dir,
                                            pla_compress_keys=("opt['v']",)))
    tel = TelemetryCompressor(eps=1e-2)
    tcfg = TrainConfig(steps=args.steps, grad_mode=args.grad_mode,
                       grad_accum=args.grad_accum,
                       ckpt_every=args.ckpt_every,
                       pla=GradCompressionConfig())
    with compat_sharding.use_mesh(mesh):
        out = run_train(api, tcfg, pipe, ckpt=ck, telemetry=tel, mesh=mesh)
    for h in out["history"]:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.3f}")
    print(f"done in {out['seconds']:.1f}s; telemetry ratio "
          f"{tel.ratio:.3f}; checkpoints: {ck.all_steps()}")


if __name__ == "__main__":
    main()
