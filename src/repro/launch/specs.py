"""ShapeDtypeStruct input stand-ins + sharding specs per (arch x shape).

Everything here is allocation-free: parameters come from
``jax.eval_shape(api.init, ...)``, inputs are ShapeDtypeStructs, and cache
structures are ``eval_shape`` of the cache constructors — the dry-run
lowers and compiles full-size programs without touching device memory.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeSpec
from repro.models.base import ModelConfig
from repro.models.zoo import ModelAPI

I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Model inputs for one cell, as ShapeDtypeStructs.

    For ``train``/``prefill``: the full token batch (+ modality stubs).
    For ``decode``: a single-token batch; the KV/state cache is built
    separately (see :func:`cache_specs`).
    """
    B, T = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        toks = _sds((B, 1), I32)
    else:
        toks = _sds((B, T), I32)
    batch: Dict[str, Any] = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm" and shape.kind != "decode":
        batch["mrope_positions"] = _sds((B, 3, T), I32)
        batch["vision_embed"] = _sds((B, T, cfg.d_model), jnp.bfloat16)
    return batch


def _bd(mesh_axes: Dict[str, int], size: int, strategy: str = "tp"):
    """Batch sharding, divisibility-aware.  The fsdp strategy also spreads
    the batch over the model axis (no feature sharding there)."""
    names = ("pod", "data", "model") if strategy == "fsdp" else \
        ("pod", "data")
    axes = []
    prod = 1
    for a in names:
        s = mesh_axes.get(a, 1)
        if s > 1 and size % (prod * s) == 0:
            axes.append(a)
            prod *= s
    return tuple(axes) if len(axes) > 1 else (axes[0] if axes else None)


def _model_dim(dim: int, mesh_axes) -> bool:
    m = mesh_axes.get("model", 1)
    return m > 1 and dim % m == 0


def batch_shard_specs(batch, mesh_axes, strategy: str = "tp") -> Any:
    def one(x):
        return P(_bd(mesh_axes, x.shape[0], strategy),
                 *([None] * (x.ndim - 1)))
    return jax.tree.map(one, batch)


# ---------------------------------------------------------------------------
# Cache specs per family
# ---------------------------------------------------------------------------

def _kv_spec(shape, mesh_axes, batch_axis: int):
    """(…, B, T, KH, hd): B over (pod,data); the *time* dim over model
    (flash-decoding style split-KV: scores stay tiny per shard and the
    softmax reduces with scalar-sized psums).  Falls back to KH, then hd,
    when T doesn't divide (e.g. whisper's 1500-frame cross KV)."""
    spec = [None] * len(shape)
    spec[batch_axis] = _bd(mesh_axes, shape[batch_axis])
    t_dim, kh, hd = shape[-3], shape[-2], shape[-1]
    if _model_dim(t_dim, mesh_axes):
        spec[-3] = "model"
    elif _model_dim(kh, mesh_axes):
        spec[-2] = "model"
    elif _model_dim(hd, mesh_axes):
        spec[-1] = "model"
    return P(*spec)


def cache_shard_specs(cfg: ModelConfig, cache, mesh_axes) -> Any:
    fam = cfg.family

    if fam in ("dense", "vlm"):
        return type(cache)(
            k=_kv_spec(cache.k.shape, mesh_axes, 1),
            v=_kv_spec(cache.v.shape, mesh_axes, 1),
            length=P())
    if fam == "moe":
        return type(cache)(
            k=_kv_spec(cache.k.shape, mesh_axes, 2),
            v=_kv_spec(cache.v.shape, mesh_axes, 2),
            length=P())
    if fam == "encdec":
        return type(cache)(
            k=_kv_spec(cache.k.shape, mesh_axes, 1),
            v=_kv_spec(cache.v.shape, mesh_axes, 1),
            xk=_kv_spec(cache.xk.shape, mesh_axes, 1),
            xv=_kv_spec(cache.xv.shape, mesh_axes, 1),
            length=P())
    if fam == "hybrid":
        def wspec(shape, baxis):  # (..., B, ..., W): W over model
            spec = [None] * len(shape)
            spec[baxis] = _bd(mesh_axes, shape[baxis])
            if _model_dim(shape[-1], mesh_axes):
                spec[-1] = "model"
            return P(*spec)
        return type(cache)(
            rec_h=wspec(cache.rec_h.shape, 2),
            rec_conv=wspec(cache.rec_conv.shape, 2),
            ring_k=_kv_spec(cache.ring_k.shape, mesh_axes, 1),
            ring_v=_kv_spec(cache.ring_v.shape, mesh_axes, 1),
            tail_h=wspec(cache.tail_h.shape, 1),
            tail_conv=wspec(cache.tail_conv.shape, 1),
            pos=P())
    if fam == "ssm":
        def sspec(shape, baxis, mdim):
            spec = [None] * len(shape)
            spec[baxis] = _bd(mesh_axes, shape[baxis])
            if _model_dim(shape[mdim], mesh_axes):
                spec[mdim] = "model"
            return P(*spec)
        return type(cache)(
            state=sspec(cache.state.shape, 1, 2),   # H over model
            conv=sspec(cache.conv.shape, 1, 3),     # conv channels
            pos=P())
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Step builders per shape kind
# ---------------------------------------------------------------------------

def make_prefill_step(api: ModelAPI):
    """Forward pass producing last-position logits (serving prefill)."""
    cfg = api.cfg

    def prefill(params, batch):
        # last_only: the hidden state is sliced to the final position
        # *before* the unembedding (computing 32k x vocab logits and
        # discarding all but one row costs GiBs per device).
        from repro.models import (mamba2, moe_lm, rglru, transformer,
                                  whisper)
        if cfg.family in ("dense",):
            logits = transformer.forward(params, batch["tokens"], cfg,
                                         remat=False, last_only=True)
        elif cfg.family == "vlm":
            logits = transformer.forward(
                params, batch["tokens"], cfg, remat=False, last_only=True,
                mrope_positions=batch["mrope_positions"],
                extra_embed=batch.get("vision_embed"))
        elif cfg.family == "moe":
            logits, _ = moe_lm.forward(params, batch["tokens"], cfg,
                                       remat=False, last_only=True)
        elif cfg.family == "hybrid":
            logits = rglru.forward(params, batch["tokens"], cfg,
                                   remat=False, last_only=True)
        elif cfg.family == "ssm":
            logits = mamba2.forward(params, batch["tokens"], cfg,
                                    remat=False, last_only=True)
        elif cfg.family == "encdec":
            logits = whisper.forward(params, batch, cfg, remat=False,
                                     last_only=True)
        return logits

    return prefill


def make_serve_step(api: ModelAPI):
    def serve(params, token, cache):
        return api.decode(params, token, cache)
    return serve


def eval_params(api: ModelAPI):
    return jax.eval_shape(api.init, jax.random.PRNGKey(0))


def eval_cache(api: ModelAPI, batch_avals, max_len: int):
    params_avals = eval_params(api)
    return jax.eval_shape(
        lambda p, b: api.make_cache(p, b, max_len), params_avals,
        batch_avals)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def demo_batch(cfg: ModelConfig, B: int, T: int, key) -> Dict[str, Any]:
    """Concrete random batch matching :func:`input_specs` (tests/examples)."""
    k1, k2 = jax.random.split(key)
    batch: Dict[str, Any] = {
        "tokens": jax.random.randint(k1, (B, T), 1, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            k2, (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(jnp.arange(T, dtype=I32), (B, 3, T))
        batch["mrope_positions"] = pos
        batch["vision_embed"] = 0.01 * jax.random.normal(
            k2, (B, T, cfg.d_model), jnp.bfloat16)
    return batch
